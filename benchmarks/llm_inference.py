"""Paper Fig. 10 analogue: end-to-end LLM decode speedup from swapping
the AllReduce implementation (llama2-70b, TP=8).

Method (no TPU in this container): the decode step's communication is
counted exactly — llama2-70b TP=8 runs 2 AllReduces per layer × 80
layers on (batch, 1, 8192) bf16 activations. We price each AllReduce
under the NCCL-role baseline vs. the MSCCL++ selector pick using the
α-β link model (calibrated to the paper's own measured latencies:
MSCCL++ cuts the 1KB AllReduce from 9.5µs to 5.0µs — we reproduce
that ratio structurally via the removed sync rounds), and combine with
the roofline compute+memory time of the decode step per batch config.

Output mirrors Fig. 10's bsz/seqlen grid with predicted decode speedup.

``decode_auto_vs_explicit`` complements the analytic grid with a REAL
(CPU-emulated) measurement: the same tiny model decoded through the
auto (GSPMD psum) step and the explicit plan-replay step
(``make_serve_step(mode="explicit")``), wall-clocked per token and
checked for bit-identical greedy output. Emitted into
``BENCH_collectives.json`` by ``run.py --json``; CPU wall time is
structure only, not TPU time. ``explicit_decode_smoke`` is the
2-device variant ``scripts/check.sh --smoke`` runs per PR.

``moe_decode_auto_vs_explicit`` is the MoE analogue: a tiny
expert-parallel model decoded both ways, the explicit path replaying
the capacity-bucketed dispatch/combine all_to_all plan per layer
(``decode_plans["moe_alltoall"]``) — the paper's §2.1 MoE collective
on the §5.2 hot path. ``moe_decode_smoke`` is its 2-device smoke.

``hybrid_decode_auto_vs_explicit`` covers the hybrid (attention+SSM)
family: the SSM branch runs per-shard on its d_inner rows and its
out-proj partial replays the same per-layer AllReduce plan as the
attention/MLP partials (3 replays per layer). ``hybrid_decode_smoke``
is its 2-device smoke. ``int8kv_decode_auto_vs_explicit`` is the int8
KV cache point: dense decode with a quantized cache both ways — the
explicit path quantizes/dequantizes against the TP-replicated scale
entries, so the plan set (and the compile counters) are identical to
the fp point.
"""
from __future__ import annotations

import time

from repro import configs
from repro.core import selector as sel
from repro.roofline.analysis import V5E

TP = 8
# paper Fig. 10 batch configurations
GRID = [(8, 1024), (16, 1024), (32, 1024), (8, 4096), (16, 4096), (32, 4096)]

# NCCL-role baseline: ring algorithm at every size + fixed stack
# overhead per call (the paper's §5.1 observation: NCCL's small-message
# latency floor is ~2x MSCCL++'s measured 5.0µs at 1KB)
_NCCL_OVERHEAD_US = 4.5


def decode_comm_us(cfg, batch: int, backend: str) -> float:
    """Per-token communication time: 2 AllReduce/layer over the TP=8
    activations (attention out-proj + MLP down-proj)."""
    nbytes = batch * cfg.d_model * 2  # bf16 activations, one token
    if backend == "nccl":
        per = sel.estimate_us("allreduce_ring", TP, nbytes) + _NCCL_OVERHEAD_US
    else:
        algo = sel.choose("all_reduce", n=TP, nbytes=nbytes)
        per = sel.estimate_us(algo, TP, nbytes)
    return 2 * cfg.n_layers * per


def decode_compute_us(cfg, batch: int, seqlen: int) -> float:
    """Roofline decode step time on 8 chips: weight streaming dominates
    (memory-bound at small batch) + KV reads."""
    param_bytes = cfg.param_count() * 2 / TP
    kv_bytes = (cfg.n_layers * batch * cfg.n_kv_heads * seqlen
                * cfg.hd * 2 * 2) / TP
    mem_s = (param_bytes + kv_bytes) / V5E.hbm_bw
    flops = 2 * cfg.param_count() * batch / TP
    comp_s = flops / V5E.peak_flops
    return max(mem_s, comp_s) * 1e6


def _bench_cfg():
    from repro.models.config import ModelConfig

    return ModelConfig(
        name="decode-bench", family="dense",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab=512, max_seq=256, dtype="float32")


def _bench_moe_cfg():
    """mixtral-shaped tiny MoE: 4 experts top-2, experts divisible by
    the EP axis sizes the bench/smoke meshes use (2, 4)."""
    from repro.models.config import ModelConfig, MoEConfig

    return ModelConfig(
        name="moe-decode-bench", family="moe",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab=512, max_seq=256, dtype="float32",
        moe=MoEConfig(num_experts=4, top_k=2))


def _bench_hybrid_cfg():
    """hymba-shaped tiny hybrid: parallel attention+SSM heads, sliding
    window — the SSM inner dim (= d_model) divides the TP axis sizes
    the bench/smoke meshes use (2, 4)."""
    from repro.models.config import ModelConfig, SSMConfig

    return ModelConfig(
        name="hybrid-decode-bench", family="hybrid", window=64,
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab=512, max_seq=256, dtype="float32",
        ssm=SSMConfig(state_dim=16))


def _run_engine(cfg, params, mesh, mode, *, batch, prompts, tokens,
                kv_quant=False):
    from repro.serve.engine import Engine, ServeConfig

    eng = Engine(cfg, params, mesh,
                 ServeConfig(batch=batch, max_kv=128, mode=mode,
                             kv_quant=kv_quant))
    assert eng.mode == mode, f"requested {mode!r}, engine fell back"
    logits = eng.prefill(prompts)
    compiles0 = eng.comm.stats["compiles"]
    t0 = time.perf_counter()
    toks = eng.decode(logits, num_tokens=tokens)
    dt = time.perf_counter() - t0
    assert eng.comm.stats["compiles"] == compiles0, \
        "decode recompiled plans instead of replaying"
    return toks, dt / tokens * 1e3, eng


def _compare_modes(cfg, *, mesh_shape, axis_names, batch, prompt_len,
                   seed, tokens, kv_quant=False):
    """Shared scaffolding of every auto-vs-explicit comparison: build
    the mesh, init params, decode the same prompts through both engine
    modes. Returns (toks_auto, toks_explicit, ms_auto, ms_explicit,
    explicit_engine)."""
    import jax
    import numpy as np
    from jax.sharding import Mesh

    from repro.distributed import sharding as shd
    from repro.distributed.step import init_sharded

    n = int(np.prod(mesh_shape))
    mesh = Mesh(np.asarray(jax.devices()[:n]).reshape(mesh_shape),
                axis_names)
    params, _ = init_sharded(cfg, mesh, shd.MeshAxes(), jax.random.key(0))
    prompts = np.random.RandomState(seed).randint(
        0, cfg.vocab, (batch, prompt_len)).astype(np.int32)
    toks_a, ms_a, _ = _run_engine(cfg, params, mesh, "auto",
                                  batch=batch, prompts=prompts,
                                  tokens=tokens, kv_quant=kv_quant)
    toks_e, ms_e, eng = _run_engine(cfg, params, mesh, "explicit",
                                    batch=batch, prompts=prompts,
                                    tokens=tokens, kv_quant=kv_quant)
    return toks_a, toks_e, ms_a, ms_e, eng


def decode_auto_vs_explicit(points=None, *, batch=4, tokens=16,
                            dp=2, tp=4) -> dict:
    """Measured auto (GSPMD psum) vs explicit (compiled-plan replay)
    decode on the same params: ms/token both ways + bit-equality of the
    greedy output. The §5.2 comparison the ROADMAP asks to record."""
    cfg = _bench_cfg()
    toks_a, toks_e, ms_a, ms_e, eng = _compare_modes(
        cfg, mesh_shape=(dp, tp), axis_names=("data", "model"),
        batch=batch, prompt_len=4, seed=0, tokens=tokens)
    point = dict(
        bench="decode_auto_vs_explicit", model=cfg.name, dp=dp, tp=tp,
        batch=batch, tokens=tokens, n_layers=cfg.n_layers,
        backend=eng.comm.backend or "xla",
        wall_ms_per_token_auto=round(ms_a, 2),
        wall_ms_per_token_explicit=round(ms_e, 2),
        speedup_explicit=round(ms_a / ms_e, 3),
        tokens_bit_identical=bool((toks_a == toks_e).all()),
        predicted_comm_us_per_token=eng.plan_report()[
            "predicted_comm_us_per_token"],
    )
    if points is not None:
        points.append(point)
    return point


def moe_decode_auto_vs_explicit(points=None, *, batch=4, tokens=16,
                                dp=2, ep=4) -> dict:
    """Measured auto (GSPMD) vs explicit (plan-replay) decode for the
    MoE family: the explicit step runs expert-parallel dispatch/combine
    through the init-compiled capacity-bucketed all_to_all plan every
    layer — the last big collective family the explicit path covers
    (ROADMAP). Records ms/token both ways, bit-equality of the greedy
    output, and the per-bucket dispatch hits of the moe_alltoall plan."""
    cfg = _bench_moe_cfg()
    toks_a, toks_e, ms_a, ms_e, eng = _compare_modes(
        cfg, mesh_shape=(dp, ep), axis_names=("data", "model"),
        batch=batch, prompt_len=4, seed=0, tokens=tokens)
    rep = eng.plan_report()
    point = dict(
        bench="moe_decode_auto_vs_explicit", model=cfg.name, dp=dp, ep=ep,
        batch=batch, tokens=tokens, n_layers=cfg.n_layers,
        experts=cfg.moe.num_experts, top_k=cfg.moe.top_k,
        backend=eng.comm.backend or "xla",
        wall_ms_per_token_auto=round(ms_a, 2),
        wall_ms_per_token_explicit=round(ms_e, 2),
        speedup_explicit=round(ms_a / ms_e, 3),
        tokens_bit_identical=bool((toks_a == toks_e).all()),
        moe_alltoall_buckets=rep["plans"]["moe_alltoall"]["buckets"],
        moe_alltoall_hits=rep["plans"]["moe_alltoall"]["hits"],
        predicted_comm_us_per_token=rep["predicted_comm_us_per_token"],
    )
    if points is not None:
        points.append(point)
    return point


def moe_decode_smoke(tokens=4) -> dict:
    """Seconds-fast 2-device explicit-MoE smoke (``scripts/check.sh
    --smoke``): EP=2 model-only mesh, asserts the explicit step
    generates through the bucketed all_to_all plan (compile counters
    flat, per-bucket hits advancing) and matches the auto path's greedy
    tokens bit-for-bit."""
    cfg = _bench_moe_cfg()
    toks_a, toks_e, _, ms_e, eng = _compare_modes(
        cfg, mesh_shape=(2,), axis_names=("model",),
        batch=2, prompt_len=3, seed=1, tokens=tokens)
    assert (toks_a == toks_e).all(), "explicit MoE decode diverged from auto"
    rep = eng.plan_report()
    a2a = rep["plans"]["moe_alltoall"]
    assert sum(a2a["hits"].values()) > 0, "moe_alltoall plan never dispatched"
    return dict(ep=2, tokens=tokens, ms_per_token=round(ms_e, 2),
                tokens_bit_identical=True,
                buckets=a2a["buckets"], hits=a2a["hits"],
                predicted_comm_us_per_token=rep[
                    "predicted_comm_us_per_token"])


def hybrid_decode_auto_vs_explicit(points=None, *, batch=4, tokens=16,
                                   dp=2, tp=4) -> dict:
    """Measured auto (GSPMD) vs explicit (plan-replay) decode for the
    hybrid attention+SSM family: the explicit step shards the SSM
    inner dim over TP (state model-sharded in the cache) and completes
    the SSM out-proj partial with its own replay of the per-layer
    AllReduce plan — 3 replays per layer instead of the dense 2.
    Closes the last ROADMAP family gap alongside int8 KV. Records
    ms/token both ways and bit-equality of the greedy output."""
    cfg = _bench_hybrid_cfg()
    toks_a, toks_e, ms_a, ms_e, eng = _compare_modes(
        cfg, mesh_shape=(dp, tp), axis_names=("data", "model"),
        batch=batch, prompt_len=4, seed=0, tokens=tokens)
    rep = eng.plan_report()
    point = dict(
        bench="hybrid_decode_auto_vs_explicit", model=cfg.name, dp=dp,
        tp=tp, batch=batch, tokens=tokens, n_layers=cfg.n_layers,
        ssm_state_dim=cfg.ssm.state_dim, window=cfg.window,
        backend=eng.comm.backend or "xla",
        wall_ms_per_token_auto=round(ms_a, 2),
        wall_ms_per_token_explicit=round(ms_e, 2),
        speedup_explicit=round(ms_a / ms_e, 3),
        tokens_bit_identical=bool((toks_a == toks_e).all()),
        allreduce_replays_per_layer=3,
        predicted_comm_us_per_token=rep["predicted_comm_us_per_token"],
    )
    if points is not None:
        points.append(point)
    return point


def int8kv_decode_auto_vs_explicit(points=None, *, batch=4, tokens=16,
                                   dp=2, tp=4) -> dict:
    """The int8 KV cache on the explicit hot path: dense decode with a
    quantized cache through both engine modes. The explicit step
    quantizes every new token against the TP-replicated scale entries
    and dequantizes per gathered head — the plan set is identical to
    the fp point (no scale collective), which the flat compile
    counters inside ``_run_engine`` assert."""
    cfg = _bench_cfg()
    toks_a, toks_e, ms_a, ms_e, eng = _compare_modes(
        cfg, mesh_shape=(dp, tp), axis_names=("data", "model"),
        batch=batch, prompt_len=4, seed=0, tokens=tokens, kv_quant=True)
    point = dict(
        bench="int8kv_decode_auto_vs_explicit", model=cfg.name, dp=dp,
        tp=tp, batch=batch, tokens=tokens, n_layers=cfg.n_layers,
        cache_dtype="int8",
        backend=eng.comm.backend or "xla",
        wall_ms_per_token_auto=round(ms_a, 2),
        wall_ms_per_token_explicit=round(ms_e, 2),
        speedup_explicit=round(ms_a / ms_e, 3),
        tokens_bit_identical=bool((toks_a == toks_e).all()),
        predicted_comm_us_per_token=eng.plan_report()[
            "predicted_comm_us_per_token"],
    )
    if points is not None:
        points.append(point)
    return point


def hybrid_decode_smoke(tokens=4) -> dict:
    """Seconds-fast 2-device explicit-hybrid smoke (``scripts/check.sh
    --smoke``): TP=2 model-only mesh, asserts the explicit step decodes
    the attention+SSM family through plan replay (compile counters
    flat inside ``_run_engine``) bit-identically to auto."""
    cfg = _bench_hybrid_cfg()
    toks_a, toks_e, _, ms_e, eng = _compare_modes(
        cfg, mesh_shape=(2,), axis_names=("model",),
        batch=2, prompt_len=3, seed=1, tokens=tokens)
    assert (toks_a == toks_e).all(), \
        "explicit hybrid decode diverged from auto"
    rep = eng.plan_report()
    return dict(tp=2, tokens=tokens, ms_per_token=round(ms_e, 2),
                tokens_bit_identical=True,
                predicted_comm_us_per_token=rep[
                    "predicted_comm_us_per_token"],
                hits=rep["plans"]["layer_allreduce"]["hits"])


def explicit_decode_smoke(tokens=4) -> dict:
    """Seconds-fast 2-device explicit-decode smoke
    (``scripts/check.sh --smoke``): TP=2 model-only mesh, asserts the
    explicit step generates, replays (compile counters flat), and
    matches the auto path's greedy tokens bit-for-bit."""
    cfg = _bench_cfg()
    toks_a, toks_e, _, ms_e, eng = _compare_modes(
        cfg, mesh_shape=(2,), axis_names=("model",),
        batch=2, prompt_len=3, seed=1, tokens=tokens)
    assert (toks_a == toks_e).all(), "explicit decode diverged from auto"
    rep = eng.plan_report()
    return dict(tp=2, tokens=tokens, ms_per_token=round(ms_e, 2),
                tokens_bit_identical=True,
                predicted_comm_us_per_token=rep[
                    "predicted_comm_us_per_token"],
                hits=rep["plans"]["layer_allreduce"]["hits"])


def main(rows=None):
    rows = rows if rows is not None else []
    cfg = configs.get_config("llama2-70b")
    for bsz, seqlen in GRID:
        comp = decode_compute_us(cfg, bsz, seqlen)
        nccl = decode_comm_us(cfg, bsz, "nccl")
        ours = decode_comm_us(cfg, bsz, "mscclpp")
        t_base = comp + nccl
        t_ours = comp + ours
        speedup = t_base / t_ours
        rows.append(("decode_llama2_70b", f"bsz{bsz}_seq{seqlen}",
                     round(t_base, 1), round(t_ours, 1),
                     f"{speedup:.3f}x",
                     f"comm {nccl:.0f}->{ours:.0f}us"))
    # prefill: compute-bound, gain should shrink (paper: <=6%)
    for bsz, seqlen in GRID[:3]:
        flops = 2 * cfg.param_count() * bsz * seqlen / TP
        comp = flops / V5E.peak_flops * 1e6
        nbytes = bsz * seqlen * cfg.d_model * 2
        nccl = 2 * cfg.n_layers * (sel.estimate_us("allreduce_ring", TP, nbytes)
                                   + _NCCL_OVERHEAD_US)
        algo = sel.choose("all_reduce", n=TP, nbytes=nbytes)
        ours = 2 * cfg.n_layers * sel.estimate_us(algo, TP, nbytes)
        speedup = (comp + nccl) / (comp + ours)
        rows.append(("prefill_llama2_70b", f"bsz{bsz}_seq{seqlen}",
                     round(comp + nccl, 1), round(comp + ours, 1),
                     f"{speedup:.3f}x", ""))
    # measured (CPU-emulated) auto-vs-explicit decode on the real engine
    p = decode_auto_vs_explicit()
    rows.append(("decode_auto_vs_explicit",
                 f"dp{p['dp']}_tp{p['tp']}_bsz{p['batch']}",
                 p["wall_ms_per_token_auto"],
                 p["wall_ms_per_token_explicit"],
                 f"{p['speedup_explicit']}x",
                 "bit-identical" if p["tokens_bit_identical"]
                 else "MISMATCH"))
    # ... and the MoE expert-parallel analogue (bucketed all_to_all plans)
    m = moe_decode_auto_vs_explicit()
    rows.append(("moe_decode_auto_vs_explicit",
                 f"dp{m['dp']}_ep{m['ep']}_bsz{m['batch']}",
                 m["wall_ms_per_token_auto"],
                 m["wall_ms_per_token_explicit"],
                 f"{m['speedup_explicit']}x",
                 "bit-identical" if m["tokens_bit_identical"]
                 else "MISMATCH"))
    # ... the hybrid attention+SSM family (SSM out-proj on the plan path)
    h = hybrid_decode_auto_vs_explicit()
    rows.append(("hybrid_decode_auto_vs_explicit",
                 f"dp{h['dp']}_tp{h['tp']}_bsz{h['batch']}",
                 h["wall_ms_per_token_auto"],
                 h["wall_ms_per_token_explicit"],
                 f"{h['speedup_explicit']}x",
                 "bit-identical" if h["tokens_bit_identical"]
                 else "MISMATCH"))
    # ... and the int8 KV cache point (quantized cache, same plan set)
    q = int8kv_decode_auto_vs_explicit()
    rows.append(("int8kv_decode_auto_vs_explicit",
                 f"dp{q['dp']}_tp{q['tp']}_bsz{q['batch']}",
                 q["wall_ms_per_token_auto"],
                 q["wall_ms_per_token_explicit"],
                 f"{q['speedup_explicit']}x",
                 "bit-identical" if q["tokens_bit_identical"]
                 else "MISMATCH"))
    return rows
