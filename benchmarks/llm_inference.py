"""Paper Fig. 10 analogue: end-to-end LLM decode speedup from swapping
the AllReduce implementation (llama2-70b, TP=8).

Method (no TPU in this container): the decode step's communication is
counted exactly — llama2-70b TP=8 runs 2 AllReduces per layer × 80
layers on (batch, 1, 8192) bf16 activations. We price each AllReduce
under the NCCL-role baseline vs. the MSCCL++ selector pick using the
α-β link model (calibrated to the paper's own measured latencies:
MSCCL++ cuts the 1KB AllReduce from 9.5µs to 5.0µs — we reproduce
that ratio structurally via the removed sync rounds), and combine with
the roofline compute+memory time of the decode step per batch config.

Output mirrors Fig. 10's bsz/seqlen grid with predicted decode speedup.

``decode_auto_vs_explicit`` complements the analytic grid with a REAL
(CPU-emulated) measurement: the same tiny model decoded through the
auto (GSPMD psum) step and the explicit plan-replay step
(``make_serve_step(mode="explicit")``), wall-clocked per token and
checked for bit-identical greedy output. Emitted into
``BENCH_collectives.json`` by ``run.py --json``; CPU wall time is
structure only, not TPU time. ``explicit_decode_smoke`` is the
2-device variant ``scripts/check.sh --smoke`` runs per PR.
"""
from __future__ import annotations

import time

from repro import configs
from repro.core import selector as sel
from repro.roofline.analysis import V5E

TP = 8
# paper Fig. 10 batch configurations
GRID = [(8, 1024), (16, 1024), (32, 1024), (8, 4096), (16, 4096), (32, 4096)]

# NCCL-role baseline: ring algorithm at every size + fixed stack
# overhead per call (the paper's §5.1 observation: NCCL's small-message
# latency floor is ~2x MSCCL++'s measured 5.0µs at 1KB)
_NCCL_OVERHEAD_US = 4.5


def decode_comm_us(cfg, batch: int, backend: str) -> float:
    """Per-token communication time: 2 AllReduce/layer over the TP=8
    activations (attention out-proj + MLP down-proj)."""
    nbytes = batch * cfg.d_model * 2  # bf16 activations, one token
    if backend == "nccl":
        per = sel.estimate_us("allreduce_ring", TP, nbytes) + _NCCL_OVERHEAD_US
    else:
        algo = sel.choose("all_reduce", n=TP, nbytes=nbytes)
        per = sel.estimate_us(algo, TP, nbytes)
    return 2 * cfg.n_layers * per


def decode_compute_us(cfg, batch: int, seqlen: int) -> float:
    """Roofline decode step time on 8 chips: weight streaming dominates
    (memory-bound at small batch) + KV reads."""
    param_bytes = cfg.param_count() * 2 / TP
    kv_bytes = (cfg.n_layers * batch * cfg.n_kv_heads * seqlen
                * cfg.hd * 2 * 2) / TP
    mem_s = (param_bytes + kv_bytes) / V5E.hbm_bw
    flops = 2 * cfg.param_count() * batch / TP
    comp_s = flops / V5E.peak_flops
    return max(mem_s, comp_s) * 1e6


def _bench_cfg():
    from repro.models.config import ModelConfig

    return ModelConfig(
        name="decode-bench", family="dense",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab=512, max_seq=256, dtype="float32")


def _run_engine(cfg, params, mesh, mode, *, batch, prompts, tokens):
    from repro.serve.engine import Engine, ServeConfig

    eng = Engine(cfg, params, mesh,
                 ServeConfig(batch=batch, max_kv=128, mode=mode))
    assert eng.mode == mode, f"requested {mode!r}, engine fell back"
    logits = eng.prefill(prompts)
    compiles0 = eng.comm.stats["compiles"]
    t0 = time.perf_counter()
    toks = eng.decode(logits, num_tokens=tokens)
    dt = time.perf_counter() - t0
    assert eng.comm.stats["compiles"] == compiles0, \
        "decode recompiled plans instead of replaying"
    return toks, dt / tokens * 1e3, eng


def decode_auto_vs_explicit(points=None, *, batch=4, tokens=16,
                            dp=2, tp=4) -> dict:
    """Measured auto (GSPMD psum) vs explicit (compiled-plan replay)
    decode on the same params: ms/token both ways + bit-equality of the
    greedy output. The §5.2 comparison the ROADMAP asks to record."""
    import jax
    import numpy as np
    from jax.sharding import Mesh

    from repro.distributed import sharding as shd
    from repro.distributed.step import init_sharded

    cfg = _bench_cfg()
    mesh = Mesh(np.asarray(jax.devices()[: dp * tp]).reshape(dp, tp),
                ("data", "model"))
    params, _ = init_sharded(cfg, mesh, shd.MeshAxes(), jax.random.key(0))
    prompts = np.random.RandomState(0).randint(
        0, cfg.vocab, (batch, 4)).astype(np.int32)

    toks_a, ms_a, _ = _run_engine(cfg, params, mesh, "auto",
                                  batch=batch, prompts=prompts, tokens=tokens)
    toks_e, ms_e, eng = _run_engine(cfg, params, mesh, "explicit",
                                    batch=batch, prompts=prompts,
                                    tokens=tokens)
    point = dict(
        bench="decode_auto_explicit", model=cfg.name, dp=dp, tp=tp,
        batch=batch, tokens=tokens, n_layers=cfg.n_layers,
        backend=eng.comm.backend or "xla",
        wall_ms_per_token_auto=round(ms_a, 2),
        wall_ms_per_token_explicit=round(ms_e, 2),
        speedup_explicit=round(ms_a / ms_e, 3),
        tokens_bit_identical=bool((toks_a == toks_e).all()),
        predicted_comm_us_per_token=eng.plan_report()[
            "predicted_comm_us_per_token"],
    )
    if points is not None:
        points.append(point)
    return point


def explicit_decode_smoke(tokens=4) -> dict:
    """Seconds-fast 2-device explicit-decode smoke
    (``scripts/check.sh --smoke``): TP=2 model-only mesh, asserts the
    explicit step generates, replays (compile counters flat), and
    matches the auto path's greedy tokens bit-for-bit."""
    import jax
    import numpy as np
    from jax.sharding import Mesh

    from repro.distributed import sharding as shd
    from repro.distributed.step import init_sharded

    cfg = _bench_cfg()
    mesh = Mesh(np.asarray(jax.devices()[:2]), ("model",))
    params, _ = init_sharded(cfg, mesh, shd.MeshAxes(), jax.random.key(0))
    prompts = np.random.RandomState(1).randint(
        0, cfg.vocab, (2, 3)).astype(np.int32)
    toks_a, _, _ = _run_engine(cfg, params, mesh, "auto",
                               batch=2, prompts=prompts, tokens=tokens)
    toks_e, ms_e, eng = _run_engine(cfg, params, mesh, "explicit",
                                    batch=2, prompts=prompts, tokens=tokens)
    assert (toks_a == toks_e).all(), "explicit decode diverged from auto"
    rep = eng.plan_report()
    return dict(tp=2, tokens=tokens, ms_per_token=round(ms_e, 2),
                tokens_bit_identical=True,
                predicted_comm_us_per_token=rep[
                    "predicted_comm_us_per_token"],
                hits=rep["plans"]["layer_allreduce"]["hits"])


def main(rows=None):
    rows = rows if rows is not None else []
    cfg = configs.get_config("llama2-70b")
    for bsz, seqlen in GRID:
        comp = decode_compute_us(cfg, bsz, seqlen)
        nccl = decode_comm_us(cfg, bsz, "nccl")
        ours = decode_comm_us(cfg, bsz, "mscclpp")
        t_base = comp + nccl
        t_ours = comp + ours
        speedup = t_base / t_ours
        rows.append(("decode_llama2_70b", f"bsz{bsz}_seq{seqlen}",
                     round(t_base, 1), round(t_ours, 1),
                     f"{speedup:.3f}x",
                     f"comm {nccl:.0f}->{ours:.0f}us"))
    # prefill: compute-bound, gain should shrink (paper: <=6%)
    for bsz, seqlen in GRID[:3]:
        flops = 2 * cfg.param_count() * bsz * seqlen / TP
        comp = flops / V5E.peak_flops * 1e6
        nbytes = bsz * seqlen * cfg.d_model * 2
        nccl = 2 * cfg.n_layers * (sel.estimate_us("allreduce_ring", TP, nbytes)
                                   + _NCCL_OVERHEAD_US)
        algo = sel.choose("all_reduce", n=TP, nbytes=nbytes)
        ours = 2 * cfg.n_layers * sel.estimate_us(algo, TP, nbytes)
        speedup = (comp + nccl) / (comp + ours)
        rows.append(("prefill_llama2_70b", f"bsz{bsz}_seq{seqlen}",
                     round(comp + nccl, 1), round(comp + ours, 1),
                     f"{speedup:.3f}x", ""))
    # measured (CPU-emulated) auto-vs-explicit decode on the real engine
    p = decode_auto_vs_explicit()
    rows.append(("decode_auto_vs_explicit",
                 f"dp{p['dp']}_tp{p['tp']}_bsz{p['batch']}",
                 p["wall_ms_per_token_auto"],
                 p["wall_ms_per_token_explicit"],
                 f"{p['speedup_explicit']}x",
                 "bit-identical" if p["tokens_bit_identical"]
                 else "MISMATCH"))
    return rows
