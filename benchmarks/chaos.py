"""Chaos bench — seeded fault injection against the robustness layer.

Proves, on the emulated 2-device mesh and in seconds, the two claims
``docs/robustness.md`` makes:

* every **static** fault class (`repro.core.faults.STATIC_KINDS`)
  injected into a registry program is rejected by the verifier before
  lowering, and
* every **runtime** fault class (`RUNTIME_KINDS`) fired inside an
  executor is detected by the engine guardrails and recovered — retry
  for transients, watchdog + auto-fallback for stalls, numeric guard +
  auto-fallback for corruption — with the decoded tokens still equal
  to the clean auto reference.

Also records the overhead point: verification cost is compile-time
(µs-scale per program); the replay hot path executes the verified
artifact unchanged, so per-token overhead is zero by construction.

Wired into ``scripts/check.sh --chaos`` and the ``--json`` payload
(``bench=chaos_*`` points).
"""
import time


def _registry_programs(sizes=(2, 4), levels=(0, 2)):
    from repro.core import algorithms as algos
    from repro.core import passes

    for name in sorted(algos.REGISTRY):
        build = algos.REGISTRY[name]
        for n in sizes:
            src = build(n, 0) if name == "broadcast_allpairs" else build(n)
            for lvl in levels:
                yield name, n, lvl, passes.optimize(src, lvl, n)


def static_rejection_matrix(seeds=(0, 1)) -> dict:
    """Inject every static fault kind into every registry program and
    count verifier rejections. Returns the matrix summary; raises if
    any mutation slips through (the mutation check of the acceptance
    criteria)."""
    from repro.core import faults
    from repro.core.verify import verify_program

    injected = rejected = 0
    codes: dict = {}
    t0 = time.perf_counter()
    for name, n, lvl, prog in _registry_programs():
        for kind in faults.STATIC_KINDS:
            for seed in seeds:
                try:
                    bad = faults.inject_program(
                        prog, faults.FaultSpec(kind, seed=seed), n)
                except ValueError:
                    continue       # program has no such instruction
                injected += 1
                report = verify_program(bad, n)
                if report.ok:
                    raise AssertionError(
                        f"verifier MISSED {kind} in {name} n={n} O{lvl} "
                        f"seed={seed}")
                rejected += 1
                for f in report.findings:
                    codes[f.code] = codes.get(f.code, 0) + 1
    wall = time.perf_counter() - t0
    return dict(injected=injected, rejected=rejected,
                finding_codes=dict(sorted(codes.items())),
                wall_s=round(wall, 2),
                verify_us_per_program=round(wall / max(injected, 1) * 1e6))


def _tiny_engine(mode, serve_kw, *, tp=2, batch=2, prompt_len=3):
    """2-device TP engine over the tiny bench model, plus its prompts."""
    import jax
    import numpy as np
    from jax.sharding import Mesh

    from benchmarks.llm_inference import _bench_cfg
    from repro.distributed import sharding as shd
    from repro.distributed.step import init_sharded
    from repro.serve.engine import Engine, ServeConfig

    cfg = _bench_cfg()
    mesh = Mesh(np.asarray(jax.devices()[:tp]).reshape(1, tp),
                ("data", "model"))
    params, _ = init_sharded(cfg, mesh, shd.MeshAxes(), jax.random.key(0))
    prompts = np.random.RandomState(0).randint(
        0, cfg.vocab, (batch, prompt_len)).astype(np.int32)
    eng = Engine(cfg, params, mesh,
                 ServeConfig(batch=batch, max_kv=32, mode=mode, **serve_kw))
    return eng, prompts


def runtime_recovery_smoke(tokens=4) -> dict:
    """Fire each runtime fault class inside the explicit engine and
    assert the guardrails detect + recover it: the decoded greedy
    tokens must equal the clean auto reference every time."""
    from repro.core import faults

    def run(eng, prompts, spec=None):
        t0 = time.perf_counter()
        if spec is None:
            toks = eng.decode(eng.prefill(prompts), num_tokens=tokens)
        else:
            with faults.inject(spec) as inj:
                toks = eng.decode(eng.prefill(prompts), num_tokens=tokens)
            assert inj.fired > 0, f"{spec.kind} never fired"
        return toks, (time.perf_counter() - t0) * 1e3

    # clean references: auto tokens are the ground truth the recovered
    # engines must reproduce
    ref_eng, prompts = _tiny_engine("auto", {})
    ref_toks, ref_ms = run(ref_eng, prompts)

    results = {}

    # fail_call: transient executor failure -> bounded retry, engine
    # STAYS explicit
    eng, _ = _tiny_engine("explicit", {})
    toks, ms = run(eng, prompts, faults.FaultSpec("fail_call", count=1))
    assert eng.mode == "explicit", "retry should recover without fallback"
    assert eng.health["retries"] >= 1
    assert (toks == ref_toks).all(), "recovered tokens diverged"
    results["fail_call"] = dict(recovered="retry", ms=round(ms, 1),
                                retries=eng.health["retries"])

    # corrupt_chunk: poisoned payload -> numeric guard detects the
    # non-finite logits, engine degrades to auto and re-runs the step
    eng, _ = _tiny_engine("explicit", dict(guard_numerics=True))
    toks, ms = run(eng, prompts, faults.FaultSpec("corrupt_chunk", count=1))
    assert eng.mode == "auto", "numeric guard should degrade to auto"
    assert eng.health["faults_detected"] >= 1
    assert (toks == ref_toks).all(), "recovered tokens diverged"
    results["corrupt_chunk"] = dict(
        recovered="numeric-guard+auto-fallback", ms=round(ms, 1),
        faults_detected=eng.health["faults_detected"])

    # stall_rank: the watchdog times the step out, engine degrades to
    # auto and re-runs the step there
    eng, _ = _tiny_engine("explicit", dict(plan_timeout_s=0.75))
    toks, ms = run(eng, prompts,
                   faults.FaultSpec("stall_rank", count=1, delay_s=5.0))
    assert eng.mode == "auto", "watchdog should degrade to auto"
    assert eng.health["timeouts"] >= 1
    assert (toks == ref_toks).all(), "recovered tokens diverged"
    results["stall_rank"] = dict(
        recovered="watchdog+auto-fallback", ms=round(ms, 1),
        timeouts=eng.health["timeouts"])

    return dict(reference_ms=round(ref_ms, 1), faults=results)


def verifier_overhead_point(points=None) -> dict:
    """Compile-time verifier cost vs. verify='off', same plans. The
    replay path executes the identical verified artifact, so per-token
    replay overhead is zero by construction — the number that matters
    is the one-off compile cost."""
    import jax.numpy as jnp

    from repro.core.comm import Communicator

    shapes = [("all_reduce", (256, 128)), ("all_gather", (32, 128)),
              ("reduce_scatter", (256, 128)), ("all_to_all", (256, 128))]

    def compile_all(verify):
        comm = Communicator("x", n=8, backend="xla", verify=verify)
        t0 = time.perf_counter()
        for coll, shape in shapes:
            comm.compile(coll, shape, jnp.float32)
        return (time.perf_counter() - t0) * 1e3, comm

    off_ms, _ = compile_all("off")
    strict_ms, comm = compile_all("strict")
    point = dict(
        bench="chaos_verifier_overhead", n=8, plans=len(shapes),
        compile_ms_off=round(off_ms, 2),
        compile_ms_strict=round(strict_ms, 2),
        verify_overhead_ms=round(strict_ms - off_ms, 2),
        verified=comm.health["verified"],
        replay_overhead_us_per_token=0.0,   # compile-time only
    )
    if points is not None:
        points.append(point)
    return point


def chaos_smoke(points=None) -> dict:
    """The full chaos smoke: static rejection matrix + runtime recovery
    + overhead point. Seconds-fast, 2-device; ``scripts/check.sh
    --chaos`` runs exactly this."""
    summary = dict(
        static=static_rejection_matrix(),
        runtime=runtime_recovery_smoke(),
        overhead=verifier_overhead_point(points),
    )
    if points is not None:
        rt = summary["runtime"]
        points.append(dict(
            bench="chaos_runtime_recovery",
            reference_ms=rt["reference_ms"],
            **{f"{k}_ms": v["ms"] for k, v in rt["faults"].items()}))
    return summary
