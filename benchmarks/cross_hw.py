"""Paper Fig. 11/12 analogue (cross-hardware portability): the same DSL
algorithms priced on two TPU generations' link models, plus the
selection crossovers per hardware. The paper's argument — the algorithm
library + selector retarget with only new hardware constants — is
demonstrated by the table itself (no algorithm code changes).

Two further sections ride on the same cost model:

* ``sweep_points`` — the widened registry at n∈{16, 32, 64}: per-size
  selector picks with every candidate's α-β estimate attached. At n=8
  the ring/1PA/2PA family barely separates; at these sizes the
  log-step algorithms (swing, recursive doubling) win the
  latency-bound middle of the range and rings keep the
  bandwidth-bound top — the separation this registry exists for.
* ``hierarchical_points`` — flat-vs-hierarchical AllReduce on the
  modeled 2D ICI×DCN mesh (4×4): the flat single-axis plan pays DCN
  for every byte, the ``HierarchicalCommunicator``'s
  RS(ICI) → AR(DCN) → AG(ICI) composition crosses DCN with 1/L of the
  payload. Points carry (n, axes, algo) metadata and land in
  ``BENCH_collectives.json`` via ``run.py --json``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import selector as sel

HW_LINKS = {
    # alpha_us, beta_GBps per link-direction aggregate
    "v5e_ici": sel.LinkModel(alpha_us=1.0, beta_GBps=50.0, torus=True),
    "v5p_ici": sel.LinkModel(alpha_us=0.8, beta_GBps=90.0, torus=True),
    "dcn": sel.DCN,
}

SIZES = [1 << 10, 1 << 13, 1 << 17, 1 << 21, 1 << 26, 1 << 30]

#: the tentpole geometries: host-device-count emulation covers n=16
#: end-to-end (tests / hier_smoke); 32 and 64 are costed analytically
SWEEP_NS = [16, 32, 64]

#: the modeled 2D mesh (local × node = 4 × 4 = 16 ranks)
MESH_LOCAL, MESH_NODE = 4, 4


def sweep_points(points: list) -> list:
    """Registry sweep: selector choice per (n, size) for all_reduce on
    v5e ICI, with every supported candidate's estimate attached so the
    crossover structure is inspectable from the JSON artifact alone."""
    for n in SWEEP_NS:
        for nbytes in SIZES:
            ests = {c: round(sel.estimate_us(c, n, nbytes), 2)
                    for c in sel.CANDIDATES["all_reduce"]
                    if sel.supports(c, n)}
            pick = sel.choose("all_reduce", n=n, nbytes=nbytes)
            points.append(dict(
                bench="registry_sweep", collective="all_reduce", n=n,
                nbytes=nbytes, algo=pick, predicted_us=ests[pick],
                ring_us=ests["allreduce_ring"], estimates=ests))
    return points


def hierarchical_points(points: list) -> list:
    """Flat single-axis vs hierarchical AllReduce on the 2D ICI×DCN
    model. Both sides are compiled plans (real programs through the
    pass pipeline and verifier), priced analytically: the flat plan on
    the DCN link its 16 ranks would actually span, the hierarchical
    plan on per-axis links (ICI intra, DCN inter)."""
    from repro.core.comm import Communicator, HierarchicalCommunicator

    L, M = MESH_LOCAL, MESH_NODE
    flat_comm = Communicator("fx", n=L * M, link=sel.DCN)
    hc = HierarchicalCommunicator("local", "node", local_n=L, node_n=M)
    cols = 128
    for nbytes in SIZES:
        rows = max(nbytes // 4 // cols, L)
        real_bytes = rows * cols * 4
        flat = flat_comm.compile("all_reduce", (rows, cols), jnp.float32)
        hier = hc.compile((rows, cols), jnp.float32)
        points.append(dict(
            bench="hier_vs_flat", collective="all_reduce", n=L * M,
            axes=dict(local=L, node=M), nbytes=real_bytes,
            algo=hier.algo, flat_algo=flat.algo,
            predicted_us=round(hier.estimate_us, 2),
            flat_predicted_us=round(flat.estimate_us, 2),
            speedup_vs_flat=round(flat.estimate_us / hier.estimate_us, 3)))
    return points


def main(rows=None):
    rows = rows if rows is not None else []
    for hw, link in HW_LINKS.items():
        for nbytes in SIZES:
            algo = sel.choose("all_reduce", n=8, nbytes=nbytes, link=link)
            est = sel.estimate_us(algo, 8, nbytes, link)
            ring = sel.estimate_us("allreduce_ring", 8, nbytes, link)
            rows.append((f"crosshw_{hw}", nbytes, algo, round(est, 1),
                         round(ring, 1), f"{ring / est:.2f}x_vs_ring"))
    for p in sweep_points([]):
        rows.append((f"sweep_n{p['n']}", p["nbytes"], p["algo"],
                     p["predicted_us"], p["ring_us"],
                     f"{p['ring_us'] / p['predicted_us']:.2f}x_vs_ring"))
    for p in hierarchical_points([]):
        rows.append(("hier_vs_flat", p["nbytes"], p["algo"],
                     p["predicted_us"], p["flat_predicted_us"],
                     f"{p['speedup_vs_flat']}x_vs_flat"))
    return rows
