"""Paper Fig. 11/12 analogue (cross-hardware portability): the same DSL
algorithms priced on two TPU generations' link models, plus the
selection crossovers per hardware. The paper's argument — the algorithm
library + selector retarget with only new hardware constants — is
demonstrated by the table itself (no algorithm code changes)."""
from __future__ import annotations

from repro.core import selector as sel

HW_LINKS = {
    # alpha_us, beta_GBps per link-direction aggregate
    "v5e_ici": sel.LinkModel(alpha_us=1.0, beta_GBps=50.0, torus=True),
    "v5p_ici": sel.LinkModel(alpha_us=0.8, beta_GBps=90.0, torus=True),
    "dcn": sel.DCN,
}

SIZES = [1 << 10, 1 << 13, 1 << 17, 1 << 21, 1 << 26, 1 << 30]


def main(rows=None):
    rows = rows if rows is not None else []
    for hw, link in HW_LINKS.items():
        for nbytes in SIZES:
            algo = sel.choose("all_reduce", n=8, nbytes=nbytes, link=link)
            est = sel.estimate_us(algo, 8, nbytes, link)
            ring = sel.estimate_us("allreduce_ring", 8, nbytes, link)
            rows.append((f"crosshw_{hw}", nbytes, algo, round(est, 1),
                         round(ring, 1), f"{ring / est:.2f}x_vs_ring"))
    return rows
